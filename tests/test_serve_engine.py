"""Continuous-batching serving engine (serve/engine.py).

Three layers, cheapest first: the pure-host `Scheduler` policy as a
deterministic state machine (no devices), the single-device engine's
core invariant — a request's greedy tokens and logits are BIT-identical
whether it runs alone or joins a batch mid-flight — and the same
invariant plus the `sample_greedy` tie-break under a real tp=2 SPMD
mesh in a subprocess."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.heap import SymmetricHeap
from repro.serve import PagedKV, PagePool, PagePoolError

PAGE_BYTES = 64
PAGE_TOKENS = 8


def make_sched(n_pages, max_slots=2, max_pages=8):
    from repro.serve.engine import Scheduler
    pool = PagePool(SymmetricHeap((n_pages + 1) * PAGE_BYTES), PAGE_BYTES)
    return Scheduler(PagedKV(pool, max_slots, max_pages), PAGE_TOKENS)


def drive(sched, trace, decode_per_step=1):
    """Run the scheduler against a synthetic trace.

    `trace[t]` is a list of (prompt_len, max_new) submissions arriving at
    step t.  Active slots "decode" `decode_per_step` tokens per step.
    Returns the flat event log [("admit"|"evict", step, rid), ...]."""
    events = []
    t = 0
    while t < len(trace) or not sched.idle():
        for plen, mnew in (trace[t] if t < len(trace) else []):
            sched.submit(np.arange(1, plen + 1), mnew)
        for slot, st in sched.step_evict():
            events.append(("evict", t, st.rid))
        for slot, st in sched.step_admit():
            events.append(("admit", t, st.rid))
        for i in sched.active_slots():
            st = sched.slots[i]
            st.out.extend([0] * decode_per_step)
            st.pos += decode_per_step
            if len(st.out) >= st.max_new:
                st.done = True
        t += 1
        assert t < 10_000, "scheduler livelock"
    for slot, st in sched.step_evict():
        events.append(("evict", t, st.rid))
    return events


# ---------------------------------------------------------------------------
# Scheduler: deterministic policy, pure host
# ---------------------------------------------------------------------------

def test_scheduler_event_order_is_deterministic():
    trace = [[(8, 4), (8, 2)], [], [(8, 3)], [(16, 2), (8, 1)]]
    ev1 = drive(make_sched(n_pages=4, max_slots=2), list(trace))
    ev2 = drive(make_sched(n_pages=4, max_slots=2), list(trace))
    assert ev1 == ev2
    # admissions happen in rid (FIFO) order
    admits = [rid for kind, _, rid in ev1 if kind == "admit"]
    assert admits == sorted(admits) == [0, 1, 2, 3, 4]
    # every admitted request is eventually evicted exactly once
    evicts = sorted(rid for kind, _, rid in ev1 if kind == "evict")
    assert evicts == [0, 1, 2, 3, 4]


def test_strict_fifo_big_request_is_not_starved():
    # 4-page heap, 2 slots.  A 4-page request sits at the head while
    # 1-page requests stream in behind it: FIFO admission must never
    # skip the head, so the big one gets in as soon as pages free up.
    sched = make_sched(n_pages=4, max_slots=2, max_pages=4)
    sched.submit(np.arange(1, 9), 8)        # rid 0: 2 pages
    sched.submit(np.arange(1, 25), 8)       # rid 1: 4 pages (the big one)
    for _ in range(6):                      # rids 2..7: 1 page each
        sched.submit(np.arange(1, 5), 4)
    events = drive(sched, [])
    admits = [rid for kind, _, rid in events if kind == "admit"]
    assert admits == list(range(8))         # strict FIFO, nobody skipped
    # while rid 1 waits for pages nothing behind it may jump the queue:
    # rid 1 is admitted strictly before rids 2..7
    t_big = next(t for k, t, r in events if k == "admit" and r == 1)
    t_small = [t for k, t, r in events if k == "admit" and r >= 2]
    assert all(t_big <= t for t in t_small)


def test_admission_backpressure_waits_without_errors():
    # heap holds 2 pages; every request needs 2 -> one in flight at a
    # time, the rest wait.  No PagePoolError/HeapError surfaces.
    sched = make_sched(n_pages=2, max_slots=4, max_pages=4)
    for _ in range(3):
        sched.submit(np.arange(1, 9), 8)    # 16 tokens -> 2 pages
    events = drive(sched, [])
    admits = [(t, rid) for k, t, rid in events if k == "admit"]
    assert [rid for _, rid in admits] == [0, 1, 2]
    # serialized: each admission waits for the previous eviction
    evict_t = {rid: t for k, t, rid in events if k == "evict"}
    assert admits[1][0] >= evict_t[0] and admits[2][0] >= evict_t[1]
    assert sched.kv.pool.live_pages() == 0  # drained clean


def test_submit_validates_against_max_pages():
    sched = make_sched(n_pages=16, max_slots=2, max_pages=2)
    with pytest.raises(ValueError):
        sched.submit(np.arange(1, 18), 8)   # 25 tokens > 2 pages
    with pytest.raises(ValueError):
        sched.submit(np.asarray([], np.int32), 4)


# ---------------------------------------------------------------------------
# Engine on SIM (single device): batched == alone, bitwise
# ---------------------------------------------------------------------------

ARCH = "qwen2-0.5b"


def _make_engine(params=None, **kw):
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import ServeEngine
    kw.setdefault("max_slots", 3)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_seq", 32)
    kw.setdefault("prompt_bucket", 16)
    return ServeEngine(smoke_config(ARCH), make_mesh(1, 1), params=params,
                       capture_logits=True, **kw)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(7)
    return [rng.integers(1, 1000, size=n).astype(np.int32)
            for n in (5, 9, 3, 12)]


def test_engine_batched_equals_alone_bitwise(prompts):
    eng = _make_engine()
    rids = [eng.submit(p, 6) for p in prompts]
    eng.run()
    assert eng.scheduler.idle()

    solo = _make_engine(params=eng.params)
    for rid, p in zip(rids, prompts):
        srid = solo.submit(p, 6)
        solo.run()
        assert np.array_equal(eng.results[rid], solo.results[srid]), rid
        # stronger than the tokens: the per-step logits are bitwise equal
        for a, b in zip(eng.logits_trace[rid], solo.logits_trace[srid]):
            assert np.array_equal(a, b)


def test_engine_mid_batch_join_is_bitwise_transparent(prompts):
    """A request that joins while others are mid-decode gets the same
    tokens as the same request submitted up front."""
    eng = _make_engine()
    r0 = eng.submit(prompts[0], 8)
    eng.step(); eng.step(); eng.step()        # r0 is 3 tokens in
    r1 = eng.submit(prompts[1], 6)            # joins mid-batch
    eng.run()

    ref = _make_engine(params=eng.params)
    q1 = ref.submit(prompts[1], 6)
    ref.run()
    assert np.array_equal(eng.results[r1], ref.results[q1])
    q0 = ref.submit(prompts[0], 8)
    ref.run()
    assert np.array_equal(eng.results[r0], ref.results[q0])


def test_engine_heap_backpressure_still_serves_everyone(prompts):
    # heap sized for ~one worst-case sequence: requests serialize through
    # admission backpressure but all finish, and nothing leaks
    probe = _make_engine()
    tight = probe.page_bytes * (4 + 1)        # 4 live pages + null
    eng = _make_engine(params=probe.params, kv_heap_bytes=tight)
    rids = [eng.submit(p, 6) for p in prompts[:3]]
    eng.run()
    assert sorted(eng.results) == sorted(rids)
    assert all(len(eng.results[r]) == 6 for r in rids)
    assert eng.scheduler.n_admitted == 3
    assert eng.kv.pool.live_pages() == 0
    # tokens unaffected by the serialization
    ref = _make_engine(params=probe.params)
    for rid, p in zip(rids, prompts[:3]):
        q = ref.submit(p, 6)
        ref.run()
        assert np.array_equal(eng.results[rid], ref.results[q])


def test_engine_eos_stops_early(prompts):
    eng = _make_engine()
    r = eng.submit(prompts[0], 8)
    eng.run()
    eos = int(eng.results[r][2])              # force eos at the 3rd token
    eng2 = _make_engine(params=eng.params, eos_id=eos)
    r2 = eng2.submit(prompts[0], 8)
    eng2.run()
    assert len(eng2.results[r2]) == 3
    assert np.array_equal(eng2.results[r2], eng.results[r][:3])


# ---------------------------------------------------------------------------
# sample_greedy tie-breaking
# ---------------------------------------------------------------------------

def test_sample_greedy_tie_matches_argmax_unsharded():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.parallel.comm import Comm
    from repro.serve import step as sstep

    mesh = make_mesh(1, 1)
    logits = np.zeros((3, 16), np.float32)
    logits[0, [2, 9, 14]] = 5.0               # three-way tie -> 2
    logits[1, :] = 1.0                        # all tied -> 0
    logits[2, 11] = 3.0                       # unique max -> 11
    with jax.set_mesh(mesh):
        def f(lg):
            comm = Comm(build.axis_spec(mesh), "shmem")
            return sstep.sample_greedy(comm, lg)
        out = np.asarray(jax.jit(build.shard_mapped(
            f, mesh, (P(),), P()))(jnp.asarray(logits)))
    assert out.tolist() == np.argmax(logits, -1).tolist() == [2, 0, 11]


# ---------------------------------------------------------------------------
# tp=2 SPMD: engine invariant + cross-shard tie-break, in a subprocess
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.parallel.comm import Comm
    from repro.serve import step as sstep
    from repro.serve.engine import ServeEngine

    mesh = make_mesh(1, 2)

    # -- cross-shard greedy tie-break: lowest GLOBAL index wins ---------
    V = 16                                     # 8 per shard
    logits = np.zeros((4, V), np.float32)
    logits[0, [3, 11]] = 5.0     # tie straddles the shard boundary -> 3
    logits[1, [9, 13]] = 5.0     # both on shard 1 -> 9
    logits[2, :] = 2.0           # all tied -> 0
    logits[3, 12] = 7.0          # unique max on shard 1 -> 12
    with jax.set_mesh(mesh):
        def f(lg):
            comm = Comm(build.axis_spec(mesh), "shmem")
            return sstep.sample_greedy(comm, lg)
        out = np.asarray(jax.jit(build.shard_mapped(
            f, mesh, (P(None, "model"),), P()))(jnp.asarray(logits)))
    ref = np.argmax(logits, -1)
    assert out.tolist() == ref.tolist() == [3, 9, 0, 12], out
    print("TIE-OK")

    # -- engine: batched == alone, bitwise, on the SAME tp=2 mesh -------
    cfg = smoke_config("qwen2-0.5b")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 1000, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    kw = dict(max_slots=3, page_size=8, max_seq=32, prompt_bucket=16,
              capture_logits=True)
    eng = ServeEngine(cfg, mesh, **kw)
    rids = [eng.submit(p, 5) for p in prompts]
    eng.run()
    solo = ServeEngine(cfg, mesh, params=eng.params, **kw)
    for rid, p in zip(rids, prompts):
        s = solo.submit(p, 5)
        solo.run()
        assert np.array_equal(eng.results[rid], solo.results[s]), rid
        for a, b in zip(eng.logits_trace[rid], solo.logits_trace[s]):
            assert np.array_equal(a, b)
    print("SPMD-ENGINE-OK")
""")


def test_spmd_engine_and_tiebreak():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "TIE-OK" in r.stdout and "SPMD-ENGINE-OK" in r.stdout
