"""Paged KV-cache bookkeeping on the symmetric heap (serve/kv.py):
page alloc/free/reuse round-trips over the brk discipline, heap
exhaustion surfacing as clean admission backpressure (`PagePoolError`,
never `HeapError`), and fragmentation-free page reuse after eviction.
Pure host code — no devices."""
import numpy as np
import pytest

from repro.core.heap import HeapError, SymmetricHeap
from repro.serve import PagedKV, PagePool, PagePoolError, pages_for

PAGE = 256          # bytes; multiple of the heap's 8-byte default align


def make_pool(n_pages: int, **kw) -> PagePool:
    # +1 for the reserved null page
    return PagePool(SymmetricHeap((n_pages + 1) * PAGE), PAGE, **kw)


# ---------------------------------------------------------------------------
# pages_for
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,ps,want", [
    (0, 8, 0), (1, 8, 1), (8, 8, 1), (9, 8, 2), (17, 8, 3), (64, 16, 4),
])
def test_pages_for(n, ps, want):
    assert pages_for(n, ps) == want


# ---------------------------------------------------------------------------
# PagePool: alloc / free / reuse round-trips
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip_restores_brk():
    pool = make_pool(4)
    assert pool.null_page == 0 and pool.live_pages() == 0
    brk0 = pool.heap.brk                       # null page only
    a = pool.alloc(3)
    assert a == [1, 2, 3] and pool.live_pages() == 3
    assert pool.heap.brk == brk0 + 3 * PAGE    # brk advanced page by page
    pool.free(reversed(a))
    # all pages free -> the pool rolled the brk back to the null page
    assert pool.live_pages() == 0
    assert pool.heap.brk == brk0
    # and the full capacity is available again
    assert pool.pages_available() == 4
    b = pool.alloc(4)
    assert sorted(b) == [1, 2, 3, 4]


def test_freed_pages_recycle_lifo_before_brk_grows():
    pool = make_pool(8)
    first = pool.alloc(2)                      # [1, 2]
    keep = pool.alloc(1)                       # [3] stays live: no trim
    pool.free(reversed(first))
    brk = pool.heap.brk
    again = pool.alloc(2)
    # the free list hands back the same pages (LIFO) without touching brk
    assert again == first
    assert pool.heap.brk == brk
    pool.free(reversed(again))
    pool.free(keep)


def test_alloc_is_all_or_nothing():
    pool = make_pool(3)
    pool.alloc(2)
    with pytest.raises(PagePoolError):
        pool.alloc(2)                          # only 1 page left
    # the rejected call held no partial reservation
    assert pool.pages_available() == 1
    assert pool.alloc(1) == [3]


def test_exhaustion_raises_pagepoolerror_not_heaperror():
    pool = make_pool(2)
    pool.alloc(2)
    with pytest.raises(PagePoolError) as ei:
        pool.alloc(1)
    assert not isinstance(ei.value, HeapError)
    # __cause__ is suppressed: callers never see heap internals
    assert ei.value.__cause__ is None


def test_double_free_and_null_free_rejected():
    pool = make_pool(2)
    (pid,) = pool.alloc(1)
    with pytest.raises(PagePoolError):
        pool.free([pool.null_page])
    pool2 = make_pool(2)
    (q,) = pool2.alloc(1)
    pool2.alloc(1)                 # keep one live so no trim resets state
    pool2.free([q])
    with pytest.raises(PagePoolError):
        pool2.free([q])
    del pid


def test_pool_requires_fresh_heap():
    heap = SymmetricHeap(4 * PAGE)
    heap.malloc(8)
    with pytest.raises(PagePoolError):
        PagePool(heap, PAGE)


def test_page_bytes_alignment_padding():
    # page_bytes gets padded up to the heap alignment so page ids stay
    # exact offset multiples
    heap = SymmetricHeap(1024, default_align=64)
    pool = PagePool(heap, 100)     # -> padded to 128
    assert pool.page_bytes == 128
    a = pool.alloc(2)
    assert a == [1, 2]


# ---------------------------------------------------------------------------
# PagedKV: admission, eviction, fragmentation-free reuse
# ---------------------------------------------------------------------------

def test_admit_fills_table_and_evict_resets_it():
    pool = make_pool(8)
    kv = PagedKV(pool, max_slots=2, max_pages=4)
    assert (kv.table == pool.null_page).all()
    sp = kv.admit(0, rid=7, n_pages=3, n_tokens=20)
    assert sp.pages == [1, 2, 3]
    assert kv.table[0, :3].tolist() == [1, 2, 3]
    assert (kv.table[0, 3:] == pool.null_page).all()
    assert (kv.table[1] == pool.null_page).all()
    assert kv.occupied() == [0] and kv.slot(0).rid == 7
    kv.evict(0)
    assert (kv.table == pool.null_page).all()
    assert kv.occupied() == [] and pool.live_pages() == 0


def test_admission_backpressure_no_heaperror_escapes():
    pool = make_pool(4)
    kv = PagedKV(pool, max_slots=4, max_pages=4)
    kv.admit(0, rid=0, n_pages=3, n_tokens=24)
    assert not kv.can_admit(2)                 # only 1 page left
    assert kv.can_admit(1)
    # forcing the admit anyway raises the pool error, not HeapError
    with pytest.raises(PagePoolError):
        kv.admit(1, rid=1, n_pages=2, n_tokens=16)
    # the failed admission left slot 1 clean and the table untouched
    assert kv.slot(1) is None
    assert (kv.table[1] == pool.null_page).all()
    kv.admit(1, rid=1, n_pages=1, n_tokens=8)  # the fitting size goes in


def test_oversized_request_rejected_by_max_pages():
    kv = PagedKV(make_pool(16), max_slots=2, max_pages=4)
    assert not kv.can_admit(5)
    with pytest.raises(PagePoolError):
        kv.admit(0, rid=0, n_pages=5, n_tokens=40)


def test_fragmentation_free_reuse_after_eviction():
    """Churn admissions through interleaved slots: every generation gets
    the same physical pages back and the brk never creeps."""
    pool = make_pool(6)
    kv = PagedKV(pool, max_slots=3, max_pages=2)
    kv.admit(0, 0, 2, 16)
    kv.admit(1, 1, 2, 16)
    kv.admit(2, 2, 2, 16)
    brk_full = pool.heap.brk
    pages1 = list(kv.slot(1).pages)
    for gen in range(10):
        kv.evict(1)                            # hole in the middle
        sp = kv.admit(1, rid=100 + gen, n_pages=2, n_tokens=16)
        assert sp.pages == pages1              # exact pages recycled
        assert pool.heap.brk == brk_full       # no brk growth, ever
    kv.evict(0), kv.evict(1), kv.evict(2)
    assert pool.heap.brk == PAGE               # back to null page only


def test_double_admit_same_slot_rejected():
    kv = PagedKV(make_pool(8), max_slots=2, max_pages=4)
    kv.admit(0, 0, 1, 8)
    with pytest.raises(PagePoolError):
        kv.admit(0, 1, 1, 8)
    with pytest.raises(PagePoolError):
        kv.evict(1)                            # empty slot


def test_table_dtype_and_null_default():
    kv = PagedKV(make_pool(4), max_slots=3, max_pages=2)
    assert kv.table.dtype == np.int32
    assert kv.table.shape == (3, 2)
    assert (kv.table == 0).all()               # null page is page 0
