"""Observability layer (DESIGN.md §16): Tracer events, quiet/fence
stall attribution, sink hardening, serving metrics, and the tracereport
schema gate."""
from __future__ import annotations

import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Profiler, Tracer, epiphany3, sim_ctx
from repro.core.trace import LEVEL_FULL, PID_HOST, PID_PE
from repro.tools.tracereport import validate_metrics, validate_trace


def _events(t, **match):
    return [e for e in t._events
            if all(e.get(k) == v for k, v in match.items())]


# ---------------------------------------------------------------------------
# Tracer: levels, spans, chrome export
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    t = Tracer(level=0)
    with t.span("x") as s:
        assert s is None
    t.instant("i")
    t.begin_async("req", 1, "r")
    t.end_async("req", 1, "r")
    assert t._events == [] and t.samples == []


def test_span_nesting_and_meta_args():
    t = Tracer(level=2)
    with t.span("outer"):
        with t.span("inner", nbytes=64.0, custom="tag"):
            pass
    names = [e["name"] for e in _events(t, ph="X")]
    assert names == ["inner", "outer"]      # inner commits first
    inner = _events(t, ph="X")[0]
    assert inner["args"]["custom"] == "tag"
    assert inner["args"]["nbytes"] == 64.0
    # nesting by time: inner contained in outer
    outer = _events(t, ph="X")[1]
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]


def test_level1_counts_but_no_events():
    t = Tracer(level=1)
    with t.span("x"):
        pass
    t.instant("i")
    assert t._events == []
    assert "span.x" in t.counters()


def test_async_request_track_roundtrip():
    t = Tracer(level=2)
    t.begin_async("request", 7, "req 7", prompt_len=5)
    t.instant_async("request", 7, "admit")
    t.end_async("request", 7, "req 7", n_tokens=3)
    phs = [e["ph"] for e in _events(t, cat="request")]
    assert phs == ["b", "n", "e"]
    assert validate_trace(t.to_chrome()) == []


def test_eager_collective_renders_stages_flows_heatmap():
    t = Tracer(level=LEVEL_FULL)
    ctx = sim_ctx(16, epiphany3(), profile=t)
    ctx.to_all(jnp.ones((16, 256), jnp.float32), algorithm="rd")
    # host-track op span with the algorithm in the name
    ops = _events(t, ph="X", pid=PID_HOST)
    assert any(e["name"] == "allreduce[rd]" for e in ops)
    # per-PE stage spans: rd on 16 PEs = 4 stages, every PE participates
    stages = _events(t, cat="stage")
    assert len(stages) == 4 * 16
    assert {e["tid"] for e in stages} == set(range(16))
    assert {e["pid"] for e in stages} == {PID_PE}
    # flow links pair up by id, src != dst
    starts = {e["id"]: e for e in _events(t, ph="s")}
    finishes = {e["id"]: e for e in _events(t, ph="f")}
    assert starts and set(starts) == set(finishes)
    for fid, s in starts.items():
        assert s["tid"] != finishes[fid]["tid"]
    # heatmap accumulated on the 4x4 topology
    hm = t.heatmap()
    assert len(hm) == 1 and hm[0]["shape"] == [4, 4]
    assert hm[0]["total_bytes"] > 0
    assert hm[0]["links"][0]["bytes"] == hm[0]["max_bytes"]
    assert validate_trace(t.to_chrome()) == []


def test_flow_cap_bounds_events():
    t = Tracer(level=LEVEL_FULL, flows_per_op=3)
    ctx = sim_ctx(16, epiphany3(), profile=t)
    ctx.to_all(jnp.ones((16, 64), jnp.float32), algorithm="ring")
    assert len(_events(t, ph="s")) <= 3


def test_event_cap_counts_drops():
    t = Tracer(level=2, max_events=2)
    for i in range(5):
        t.instant(f"i{i}")
    assert len(t._events) == 2 and t.events_dropped == 3


def test_traced_collective_uses_predicted_duration():
    """A Comm-in-jit collective commits at trace time with wall~0; its
    stage spans must still have nonzero duration."""
    import jax

    t = Tracer(level=LEVEL_FULL)
    ctx = sim_ctx(16, epiphany3(), profile=t)

    jax.jit(lambda v: ctx.to_all(v, algorithm="rd"))(
        jnp.ones((16, 256), jnp.float32))
    stages = _events(t, cat="stage")
    assert stages, "staged collective rendered no stage spans"
    assert all(e["dur"] > 0 for e in stages)
    assert all(e["args"].get("traced") for e in stages)


# ---------------------------------------------------------------------------
# quiet/fence stall attribution
# ---------------------------------------------------------------------------

def test_quiet_splits_stall_from_issue():
    prof = Profiler(level=2)
    ctx = sim_ctx(4, profile=prof)
    c = ctx.ctx_create()
    c.put_nbi(jnp.ones((4, 128)), [(i, (i + 1) % 4) for i in range(4)])
    c.quiet()
    sync = [s for s in prof.samples if s.kind == "sync"]
    assert len(sync) == 1 and sync[0].collective == "quiet"
    s = sync[0]
    assert s.issue_s > 0 and s.stall_s >= 0
    assert s.wall_s == pytest.approx(s.issue_s + s.stall_s)
    c2 = prof.counters()["sync.quiet"]
    assert c2["issue_s"] == pytest.approx(s.issue_s)
    assert c2["stall_s"] == pytest.approx(s.stall_s)


def test_fence_reports_issue_only():
    prof = Profiler(level=2)
    ctx = sim_ctx(4, profile=prof)
    c = ctx.ctx_create()
    c.put_nbi(jnp.ones((4, 32)), [(i, (i + 1) % 4) for i in range(4)])
    c.fence()
    sync = [s for s in prof.samples if s.kind == "sync"]
    assert len(sync) == 1 and sync[0].collective == "fence"
    assert sync[0].issue_s > 0 and sync[0].stall_s == 0.0
    c.quiet()       # queue still drains normally after the fence


def test_quiet_sync_renders_stall_child_span():
    t = Tracer(level=2)
    ctx = sim_ctx(4, profile=t)
    c = ctx.ctx_create()
    c.put_nbi(jnp.ones((4, 4096)), [(i, (i + 1) % 4) for i in range(4)])
    c.quiet()
    qevs = _events(t, ph="X", cat="sync")
    assert len(qevs) == 1
    a = qevs[0]["args"]
    assert a["issue_us"] >= 0 and a["stall_us"] >= 0
    stall = _events(t, cat="stall")
    if a["stall_us"] > 0:
        assert len(stall) == 1
        # the stall child starts where issue ends
        assert stall[0]["ts"] == pytest.approx(
            qevs[0]["ts"] + a["issue_us"])


def test_quiet_untimed_inside_jit():
    """Under jit tracing, quiet must not call block_until_ready (no sync
    sample — wall time there is meaningless)."""
    import jax

    prof = Profiler(level=2)
    ctx = sim_ctx(4, profile=prof)

    def f(x):
        c = ctx.ctx_create()
        c.put_nbi(x, [(i, (i + 1) % 4) for i in range(4)])
        return c.quiet()

    jax.jit(f)(jnp.ones((4, 16)))
    assert not any(s.kind == "sync" for s in prof.samples)


# ---------------------------------------------------------------------------
# satellite: sink hardening + mid-run pcontrol transitions
# ---------------------------------------------------------------------------

def test_raising_sink_does_not_abort_op_and_is_dropped():
    prof = Profiler(level=2)
    good: list = []

    def bad_sink(s):
        raise RuntimeError("observer bug")

    prof.add_sink(bad_sink)
    prof.add_sink(good.append)
    for i in range(5):
        with prof.op(f"op{i}"):
            pass
    # every op completed; the good sink saw them all
    assert len(good) == 5
    assert len(prof.samples) == 5
    # the bad sink failed MAX times then was dropped
    assert prof.sink_errors == Profiler.SINK_MAX_FAILURES
    assert prof.sinks_dropped == 1
    assert bad_sink not in prof._sinks and good.append in prof._sinks
    j = prof.to_json()
    assert j["sink_errors"] == Profiler.SINK_MAX_FAILURES
    assert j["sinks_dropped"] == 1


def test_flaky_sink_survives_with_consecutive_reset():
    prof = Profiler(level=1)
    calls = {"n": 0}

    def flaky(s):
        calls["n"] += 1
        if calls["n"] % 2:          # fails every other call
            raise ValueError("flaky")

    prof.add_sink(flaky)
    for i in range(6):
        with prof.op("x"):
            pass
    # never SINK_MAX_FAILURES consecutive failures -> never dropped
    assert prof.sinks_dropped == 0 and flaky in prof._sinks
    assert prof.sink_errors == 3


def test_pcontrol_transition_while_op_open():
    prof = Profiler(level=2)
    with prof.op("a") as s:
        assert s is not None
        prof.pcontrol(0)            # disabled mid-op
    # the op opened under level 2 was dropped at commit (disabled)
    assert prof.samples == [] and prof.counters() == {}
    with prof.op("b") as s:
        assert s is None            # fully off now
        prof.pcontrol(2)            # re-enabled mid-op
    # "b" opened disabled: no sample; the next op records normally
    assert prof.samples == []
    with prof.op("c"):
        pass
    assert [s.collective for s in prof.samples] == ["c"]


def test_pcontrol_toggle_during_eager_collectives():
    prof = Profiler(level=2)
    ctx = sim_ctx(8, profile=prof)
    x = jnp.ones((8, 64))
    ctx.to_all(x)
    prof.pcontrol(0)
    ctx.to_all(x)
    prof.pcontrol(2)
    ctx.to_all(x)
    recorded = [s for s in prof.samples if s.kind == "collective"]
    assert len(recorded) == 2


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_histogram_buckets_and_percentiles():
    from repro.serve.metrics import Histogram
    h = Histogram("lat", lo=1e-4, hi=1.0, n_buckets=8)
    for v in (1e-5, 1e-3, 1e-2, 0.5, 2.0):
        h.observe(v)
    assert h.count == 5
    assert h.buckets[0] == 1              # underflow
    assert h.buckets[-1] == 1             # overflow
    assert sum(h.buckets) == h.count
    assert h.percentile(50) == 1e-2
    assert h.percentile(0) == 1e-5 and h.percentile(100) == 2.0
    assert h.mean == pytest.approx(sum((1e-5, 1e-3, 1e-2, 0.5, 2.0)) / 5)
    assert math.isnan(Histogram("e").percentile(50))


def test_registry_types_and_export(tmp_path):
    from repro.serve.metrics import MetricsRegistry
    r = MetricsRegistry()
    r.counter("c").inc(3)
    r.gauge("g").set(1.5)
    r.histogram("h").observe(0.25)
    assert r.counter("c") is r["c"]       # idempotent get
    with pytest.raises(TypeError):
        r.gauge("c")                      # type mismatch
    p = tmp_path / "m.json"
    r.dump(p)
    doc = json.loads(p.read_text())
    assert validate_metrics(doc) == []
    assert doc["metrics"]["c"]["value"] == 3
    assert doc["metrics"]["g"]["min"] == 1.5
    assert doc["metrics"]["h"]["count"] == 1


def test_serve_metrics_lifecycle_math():
    from repro.serve.metrics import ServeMetrics
    m = ServeMetrics()
    m.on_submit(0)
    m.on_admit(0)
    m.on_first_token(0)
    m.on_decode_step(1, 0.002)
    m.on_decode_step(1, 0.004)
    m.on_evict(0)
    m.on_backpressure()
    assert m.requests_completed.value == 1
    assert m.tokens_generated.value == 3          # first + 2 decode
    assert m.ttft_s.count == 1 and m.e2e_s.count == 1
    assert m.per_token_s.percentile(50) in (0.002, 0.004)
    assert m.backpressure_waits.value == 1
    assert m._submit_t == {}                      # evict cleans up


# ---------------------------------------------------------------------------
# engine + launcher integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_engine_run():
    from repro.configs import smoke_config
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import ServeEngine
    from repro.serve.metrics import ServeMetrics

    tracer = Tracer(level=LEVEL_FULL)
    metrics = ServeMetrics()
    metrics.attach(tracer)
    eng = ServeEngine(smoke_config("qwen2-0.5b"), make_mesh(1, 1),
                      max_slots=2, page_size=8, max_seq=32,
                      prompt_bucket=16, profile=tracer, metrics=metrics)
    rng = np.random.default_rng(3)
    rids = [eng.submit(rng.integers(1, 500, size=n, dtype=np.int32), 4)
            for n in (5, 9, 3)]
    eng.run()
    return eng, tracer, metrics, rids


def test_engine_emits_request_lifecycle(traced_engine_run):
    eng, tracer, metrics, rids = traced_engine_run
    req = [e for e in tracer._events if e.get("cat") == "request"]
    begins = [e for e in req if e["ph"] == "b"]
    ends = [e for e in req if e["ph"] == "e"]
    assert len(begins) == len(rids) and len(ends) == len(rids)
    assert {e["id"] for e in begins} == {str(r) for r in rids}
    marks = {e["name"] for e in req if e["ph"] == "n"}
    assert {"admit", "first_token"} <= marks
    spans = {e["name"] for e in tracer._events if e.get("ph") == "X"}
    assert {"serve.step", "serve.prefill", "serve.decode"} <= spans


def test_engine_metrics_consistent(traced_engine_run):
    eng, tracer, metrics, rids = traced_engine_run
    n = len(rids)
    assert metrics.requests_submitted.value == n
    assert metrics.requests_completed.value == n
    assert metrics.ttft_s.count == n
    assert metrics.e2e_s.count == n
    # 4 tokens per request: 1 prefill + 3 decode each
    assert metrics.tokens_generated.value == 4 * n
    assert metrics.kv_pages_live.value == 0       # drained clean
    assert metrics.kv_occupancy.value == 0.0
    doc = metrics.to_json()
    assert validate_metrics(doc) == []
    assert "heatmap" in doc and "wire" in doc     # tracer attached


def test_trace_document_validates(traced_engine_run, tmp_path):
    _, tracer, _, _ = traced_engine_run
    p = tmp_path / "trace.json"
    tracer.dump_chrome(p)
    doc = json.loads(p.read_text())
    assert validate_trace(doc) == []
    assert doc["repro"]["level"] == LEVEL_FULL


def test_tracereport_cli(traced_engine_run, tmp_path, capsys):
    from repro.tools import tracereport
    _, tracer, metrics, _ = traced_engine_run
    tp, mp = tmp_path / "t.json", tmp_path / "m.json"
    tracer.dump_chrome(tp)
    metrics.dump(mp)
    tracereport.main([str(tp), "--metrics", str(mp), "--check"])
    out = capsys.readouterr().out
    assert "schema check OK" in out
    assert "serve.step" in out
    assert "serve.per_token_s" in out


def test_validate_catches_corruption(traced_engine_run, tmp_path):
    _, tracer, _, _ = traced_engine_run
    doc = tracer.to_chrome()
    doc["traceEvents"].append({"ph": "X", "name": "bad"})  # no ts/dur
    assert validate_trace(doc)
    assert validate_metrics({"schema": 2, "metrics": {}})
    assert validate_metrics(
        {"schema": 1, "metrics": {"x": {"type": "wat"}}})


def test_pagepool_occupancy_fragmentation():
    from repro.serve.kv import PagePool
    pool = PagePool(8 * 4096, 4096)               # 8 pages incl. null
    assert pool.occupancy() == 0.0
    assert pool.fragmentation() == 0.0
    got = pool.alloc(3)
    assert pool.occupancy() == pytest.approx(3 / 7)
    pool.free([got[-1]])
    assert pool.fragmentation() == pytest.approx(1 / 5)
    pool.free(reversed(got[:-1]))
    assert pool.occupancy() == 0.0 and pool.fragmentation() == 0.0


def test_launch_serve_trace_flags(tmp_path):
    from repro.launch import serve as serve_launch
    tout = tmp_path / "trace.json"
    mout = tmp_path / "metrics.json"
    serve_launch.main([
        "--arch", "qwen2-0.5b", "--smoke", "--batch", "2",
        "--prompt-len", "8", "--tokens", "4",
        "--trace-out", str(tout), "--metrics-out", str(mout)])
    tdoc = json.loads(tout.read_text())
    mdoc = json.loads(mout.read_text())
    assert validate_trace(tdoc) == []
    assert validate_metrics(mdoc) == []
    assert mdoc["metrics"]["serve.requests_completed"]["value"] == 2
