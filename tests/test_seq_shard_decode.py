"""Sequence-sharded KV decode (the long_500k path): cache sharded over
`data`, partial softmax stats combined with shmem reductions — must equal
the unsharded decode within a per-dtype bound, for both 2-way and 4-way
sharding.  The decode step's Comm carries a Profiler, so the test also
proves the per-step collectives land in the profiler timeline (the
serving engine relies on that wiring, DESIGN.md §15).  Subprocess with
4 host devices."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.core import Profiler
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import transformer
    from repro.parallel.comm import AxisSpec
    from repro.serve import step as sstep

    arch = "gemma2-9b"           # local/global mix exercises both masks
    base = smoke_config(arch)
    B, T, S = 1, 10, 16
    rng = np.random.default_rng(0)
    toks = rng.integers(1, base.vocab, size=(B, T)).astype(np.int32)

    # numerical headroom scales with the compute dtype: the sharded path
    # reorders the softmax reductions, so bf16 rounding admits visible
    # drift while f32 must stay tight.
    TOL = {"bfloat16": 5e-2, "float32": 5e-4}

    def run(cfg, seq_shards, mesh, profiler=None):
        dp, tp, _ = build.mesh_dims(mesh)
        with jax.set_mesh(mesh):
            init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
            params = jax.jit(init_fn)(jax.random.key(3))
            cshapes = jax.eval_shape(lambda: transformer.init_cache(
                cfg, tp, B, S, seq_shards))
            from repro.parallel import sharding
            cspecs = sharding.cache_specs(cfg, cshapes,
                                          build.mesh_axes(mesh), seq_shards)
            cache = jax.jit(build.shard_mapped(
                lambda: transformer.init_cache(cfg, tp, B, S, seq_shards),
                mesh, (), cspecs))()
            decode = sstep.build_decode_step(cfg, build.axis_spec(mesh),
                                             "shmem", seq_shards,
                                             profile=profiler)
            bspec = {"tokens": P(), "positions": P()}
            logits_spec = P(None, None, "model") if tp > 1 else P()
            djit = jax.jit(build.shard_mapped(
                decode, mesh, (specs, cspecs, bspec),
                (logits_spec, cspecs)))
            outs = []
            for t in range(T):
                logits, cache = djit(
                    params, cache,
                    {"tokens": jnp.asarray(toks[:, t:t + 1]),
                     "positions": jnp.full((B,), t, jnp.int32)})
                outs.append(np.asarray(logits[:, 0], np.float32))
            return np.stack(outs, 1)

    for dtype in (jnp.bfloat16, jnp.float32):
        cfg = dataclasses.replace(base, dtype=dtype)
        tol = TOL[jnp.dtype(dtype).name]
        ref = run(cfg, 1, make_mesh(1, 1))
        for shards in (2, 4):
            prof = Profiler(level=2)
            shrd = run(cfg, shards, make_mesh(shards, 1), profiler=prof)
            err = np.abs(ref - shrd).max()
            print(f"dtype={jnp.dtype(dtype).name} shards={shards} "
                  f"max err {err:.2e} (tol {tol:.0e})")
            assert err < tol, (dtype, shards, err)
            # the decode step's softmax-stat combines were traced through
            # the profiled Comm: selection samples name the collective
            sels = [s for s in prof.samples if s.collective == "allreduce"]
            assert sels, "decode collectives missing from profiler"
            assert all(s.traced for s in sels)
            assert all(s.n_pes == shards for s in sels if s.n_pes)
    print("SEQ-SHARD-OK")
""")


def test_seq_sharded_decode_matches_unsharded():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SEQ-SHARD-OK" in r.stdout
