"""Sequence-sharded KV decode (the long_500k path): cache sharded over
`data`, partial softmax stats combined with shmem reductions — must equal
the unsharded decode exactly.  Subprocess with 4 host devices."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import transformer
    from repro.parallel.comm import AxisSpec
    from repro.serve import step as sstep

    arch = "gemma2-9b"           # local/global mix exercises both masks
    cfg = smoke_config(arch)
    B, T, S = 1, 10, 16
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, size=(B, T)).astype(np.int32)

    def run(seq_shards, mesh):
        dp, tp, _ = build.mesh_dims(mesh)
        with jax.set_mesh(mesh):
            init_fn, shapes, specs = build.make_init_fn(cfg, mesh)
            params = jax.jit(init_fn)(jax.random.key(3))
            gp = jax.tree.map(np.asarray, params)   # global views
            S_local = S // seq_shards
            cshapes = jax.eval_shape(lambda: transformer.init_cache(
                cfg, tp, B, S, seq_shards))
            from repro.parallel import sharding
            cspecs = sharding.cache_specs(cfg, cshapes,
                                          build.mesh_axes(mesh), seq_shards)
            cache = jax.jit(build.shard_mapped(
                lambda: transformer.init_cache(cfg, tp, B, S, seq_shards),
                mesh, (), cspecs))()
            decode = sstep.build_decode_step(cfg, build.axis_spec(mesh),
                                             "shmem", seq_shards)
            bspec = {"tokens": P(), "positions": P()}
            logits_spec = P(None, None, "model") if tp > 1 else P()
            djit = jax.jit(build.shard_mapped(
                decode, mesh, (specs, cspecs, bspec),
                (logits_spec, cspecs)))
            outs = []
            for t in range(T):
                logits, cache = djit(
                    params, cache,
                    {"tokens": jnp.asarray(toks[:, t:t + 1]),
                     "positions": jnp.full((B,), t, jnp.int32)})
                outs.append(np.asarray(logits[:, 0], np.float32))
            return np.stack(outs, 1), gp

    ref, gp1 = run(1, make_mesh(1, 1))
    shrd, gp4 = run(4, make_mesh(4, 1))
    # same init key + tp=1 both ways -> identical params
    for a, b in zip(jax.tree.leaves(gp1), jax.tree.leaves(gp4)):
        assert a.shape == b.shape
    err = np.abs(ref - shrd).max()
    print("max err", err)
    assert err < 0.05, err
    print("SEQ-SHARD-OK")
""")


def test_seq_sharded_decode_matches_unsharded():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "SEQ-SHARD-OK" in r.stdout
