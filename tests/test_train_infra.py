"""Training-infrastructure tests: optimizer (incl. int8 moments),
checkpoint/restart/elastic, data pipeline determinism, fused grad sync."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt import manager as ckpt
from repro.data.pipeline import SyntheticLM
from repro.train import optimizer as opt


def _ref_adamw(p, g, m, v, t, c):
    m = c.b1 * m + (1 - c.b1) * g
    v = c.b2 * v + (1 - c.b2) * g * g
    mh = m / (1 - c.b1 ** t)
    vh = v / (1 - c.b2 ** t)
    upd = mh / (np.sqrt(vh) + c.eps)
    if p.ndim >= 2:
        upd = upd + c.weight_decay * p
    return p - c.lr * upd, m, v


def test_adamw_f32_matches_reference():
    c = opt.AdamWConfig()
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal((8, 16)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = opt.init_state(params, c)
    m = np.zeros_like(p0)
    v = np.zeros_like(p0)
    pr = p0.copy()
    for t in range(1, 4):
        g = rng.standard_normal(p0.shape).astype(np.float32)
        params, state = opt.apply_updates(params, {"w": jnp.asarray(g)},
                                          state, c)
        pr, m, v = _ref_adamw(pr, g, m, v, t, c)
        np.testing.assert_allclose(np.asarray(params["w"]), pr, rtol=2e-5,
                                   atol=1e-6)


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_quantized_moments_track_f32(dtype):
    cq = opt.AdamWConfig(moment_dtype=dtype)
    cf = opt.AdamWConfig(moment_dtype="f32")
    rng = np.random.default_rng(1)
    p0 = rng.standard_normal((16, 160)).astype(np.float32)
    pq = {"w": jnp.asarray(p0)}
    pf = {"w": jnp.asarray(p0)}
    sq = opt.init_state(pq, cq)
    sf = opt.init_state(pf, cf)
    for t in range(5):
        g = rng.standard_normal(p0.shape).astype(np.float32) * 0.1
        pq, sq = opt.apply_updates(pq, {"w": jnp.asarray(g)}, sq, cq)
        pf, sf = opt.apply_updates(pf, {"w": jnp.asarray(g)}, sf, cf)
    rel = (np.abs(np.asarray(pq["w"]) - np.asarray(pf["w"])).max()
           / (np.abs(np.asarray(pf["w"]) - p0).max() + 1e-9))
    # quantized moments stay within a few percent of the f32 trajectory
    assert rel < (0.02 if dtype == "bf16" else 0.10), rel


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((1000,)).astype(np.float32)
    enc = opt._q_encode(jnp.asarray(x), "int8")
    dec = np.asarray(opt._q_decode(enc, "int8", (1000,)))
    blk = np.abs(x).reshape(-1, 125 if False else 1)  # per-128 blocks
    err = np.abs(dec - x)
    scale = np.abs(x).max()
    assert err.max() <= scale / 127.0 * 1.01 + 1e-7


def test_checkpoint_save_restore_atomic():
    with tempfile.TemporaryDirectory() as d:
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "opt": {"step": jnp.asarray(7)}}
        ckpt.save(d, 7, state)
        assert ckpt.latest_step(d) == 7
        step, restored = ckpt.restore(d, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        assert step == 7
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.arange(12.0).reshape(3, 4))
        # second save supersedes; LATEST flips atomically
        ckpt.save(d, 9, state)
        assert ckpt.latest_step(d) == 9


def test_checkpoint_elastic_reshard():
    """Shrinking the data axis (node loss): restore() reshapes into the
    new global template."""
    with tempfile.TemporaryDirectory() as d:
        state = {"w": jnp.asarray(np.arange(32, dtype=np.float32)
                                  .reshape(8, 4))}
        ckpt.save(d, 1, state)
        tgt = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        _, restored = ckpt.restore(d, tgt)
        assert restored["w"].shape == (4, 4)


def test_fault_tolerance_manager():
    with tempfile.TemporaryDirectory() as d:
        ft = ckpt.FaultToleranceManager(d, save_every=2, async_save=False,
                                        step_deadline_s=1e-9)
        state = {"w": jnp.ones((2, 2))}
        for s in range(5):
            ft.on_step(s, lambda: state)
        ft.finalize(5, lambda: state)
        assert ckpt.latest_step(d) == 5
        assert len(ft.stragglers) >= 1   # deadline was epsilon: all stall


def test_pipeline_deterministic_and_sharded():
    p = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=3)
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch(6)
    assert (a["tokens"] != c["tokens"]).any()
    assert a["tokens"].min() >= 1 and a["tokens"].max() < 100
    # targets are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_pipeline_prefetch_iterator():
    p = SyntheticLM(vocab=50, seq_len=8, global_batch=2, seed=0)
    it = p.iterate(start_step=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], p.batch(3)["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3))
def test_fused_grad_sync_equals_unfused(nleaves, seed):
    """Heap-fused bucketed allreduce == per-tensor allreduce (sim via
    1-PE comm is identity; structural equivalence checked on trees)."""
    from repro.train.step import fused_grad_sync
    from repro.parallel.comm import AxisSpec, Comm
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(seed)
    grads = {f"g{i}": jnp.asarray(
        rng.standard_normal((3, 5)).astype(np.float32))
        for i in range(nleaves)}
    mask = {k: True for k in grads}
    mesh = make_mesh(1, 1)

    def run(fuse):
        def body(g):
            comm = Comm(AxisSpec(), "shmem")
            return fused_grad_sync(comm, g, mask, fuse=fuse)
        spec = jax.tree.map(lambda _: P(), grads)
        with jax.set_mesh(mesh):
            return jax.jit(jax.shard_map(
                body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                check_vma=False))(grads)

    a = run(True)
    b = run(False)
    for k in grads:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6)
