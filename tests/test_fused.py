"""Fused comm-compute paths (DESIGN.md §14): ring attention vs monolithic
flash, fused reduce-scatter->AdamW vs the unfused composition (bitwise),
the k-ary combine stage on int payloads, pricing/tuner wiring, and the
ops-layer pad-plan/executor cache being re-trace-free."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives as coll
from repro.core import fusion, shmem
from repro.core.netops import SimNetOps
from repro.kernels import fused_update as fu
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# ring attention (SIM): allclose-f32 vs monolithic flash
# ---------------------------------------------------------------------------

def _shard_seq(x, n):
    """(B, H, L, D) -> (n, B, H, L/n, D): PE p holds rows [p*L/n, ...)."""
    B, H, L, D = x.shape
    return x.reshape(B, H, n, L // n, D).transpose(2, 0, 1, 3, 4)


def _unshard_seq(x):
    n, B, H, Ls, D = x.shape
    return x.transpose(1, 2, 0, 3, 4).reshape(B, H, n * Ls, D)


@pytest.mark.parametrize("causal,window,hkv,use_pallas", [
    (True, None, 4, False),          # dense causal
    (False, None, 4, False),         # bidirectional
    (True, 10, 2, False),            # sliding window + GQA
    (True, None, 2, True),           # GQA through the pallas partials
    (True, 6, 4, True),              # window through the pallas partials
])
def test_ring_attention_matches_mono(causal, window, hkv, use_pallas):
    n, B, Hq, L, D = 4, 2, 4, 32, 16
    rng = np.random.default_rng(0)
    q = rng.standard_normal((B, Hq, L, D)).astype(np.float32)
    k = rng.standard_normal((B, hkv, L, D)).astype(np.float32)
    v = rng.standard_normal((B, hkv, L, D)).astype(np.float32)
    ref = kops.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window, use_pallas=False)
    ctx = shmem.sim_ctx(n)
    pos = jnp.arange(L, dtype=jnp.int32).reshape(n, L // n)
    out = fusion.ring_attention(
        ctx, _shard_seq(jnp.asarray(q), n), _shard_seq(jnp.asarray(k), n),
        _shard_seq(jnp.asarray(v), n), pos, pos, causal=causal,
        window=window, use_pallas=use_pallas, bq=8, bk=8)
    err = np.abs(_unshard_seq(np.asarray(out)) - np.asarray(ref)).max()
    assert err < 2e-5, err


def test_ring_attention_n1_is_mono():
    B, H, L, D = 1, 2, 16, 8
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((B, H, L, D)).astype(np.float32))
    ref = kops.attention(q, q, q, causal=True, use_pallas=False)
    ctx = shmem.sim_ctx(1)
    pos = jnp.arange(L, dtype=jnp.int32)[None]
    out = fusion.ring_attention(ctx, q[None], q[None], q[None], pos, pos,
                                causal=True)
    assert np.abs(np.asarray(out[0]) - np.asarray(ref)).max() < 2e-5


# ---------------------------------------------------------------------------
# fused reduce-scatter -> AdamW (SIM): bitwise vs the unfused composition
# ---------------------------------------------------------------------------

_HP = dict(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd_coef=0.1)


def _fused_fn(net, n, total, wd, out_dtype=None, use_pallas=False):
    chunk = -(-total // n)

    def fused(g, p, m, v):
        t = jnp.asarray(1.0, jnp.float32)
        c1 = 1.0 - _HP["b1"] ** t
        c2 = 1.0 - _HP["b2"] ** t
        new_p, new_m, new_v, info = fusion.fused_rs_adam(
            net, g, p, m, v, wd, c1, c2, scale=float(n),
            out_dtype=out_dtype, use_pallas=use_pallas, **_HP)
        full = coll.allgather_unpad(net, new_p, info)
        return full, new_m, new_v

    return fused, chunk


def _unfused_fn(net, n, wd):
    def unfused(g, p, m, v):
        t = jnp.asarray(1.0, jnp.float32)
        c1 = 1.0 - _HP["b1"] ** t
        c2 = 1.0 - _HP["b2"] ** t
        own, info = coll.reduce_scatter(net, g)
        gm = coll.allgather_unpad(net, own, info) / float(n)
        m = _HP["b1"] * m + (1.0 - _HP["b1"]) * gm
        v = _HP["b2"] * v + (1.0 - _HP["b2"]) * gm * gm
        upd = (m / c1) / (jnp.sqrt(v / c2) + _HP["eps"])
        upd = jnp.where(wd != 0, upd + _HP["wd_coef"] * p, upd)
        return p - _HP["lr"] * upd, m, v

    return unfused


@pytest.mark.parametrize("total", [1000, 1003])   # even / ragged chunking
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_rs_adam_bitwise(total, use_pallas):
    """jit(fused) == jit(unfused RS+AG+Adam) BITWISE for f32 — both sides
    under jit so XLA's FMA contraction applies to both (the kernel doc's
    identity contract)."""
    n = 4
    net = SimNetOps(n)
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((n, total)).astype(np.float32))
    p = jnp.asarray(np.broadcast_to(
        rng.standard_normal(total).astype(np.float32), (n, total)).copy())
    wd = jnp.asarray((np.arange(total) < total // 2).astype(np.int8))
    fused, chunk = _fused_fn(net, n, total, wd, use_pallas=use_pallas)
    unfused = _unfused_fn(net, n, wd)
    m0 = jnp.zeros((n, chunk), jnp.float32)
    v0 = jnp.zeros((n, chunk), jnp.float32)
    mf0 = jnp.zeros((n, total), jnp.float32)
    vf0 = jnp.zeros((n, total), jnp.float32)
    pf, mf_c, vf_c = jax.jit(fused)(g, p, m0, v0)
    pu, mu, vu = jax.jit(unfused)(g, p, mf0, vf0)
    np.testing.assert_array_equal(np.asarray(pf), np.asarray(pu))
    # every PE left with the identical updated bucket
    assert all(np.array_equal(np.asarray(pf[0]), np.asarray(pf[r]))
               for r in range(n))
    # owned moment chunks == the matching slices of the full moments
    padded = chunk * n
    mu_pad = np.pad(np.asarray(mu), ((0, 0), (0, padded - total)))
    vu_pad = np.pad(np.asarray(vu), ((0, 0), (0, padded - total)))
    for r in range(n):
        own = (r + 1) % n
        sl = slice(own * chunk, (own + 1) * chunk)
        valid = min(chunk, max(0, total - own * chunk))
        np.testing.assert_array_equal(np.asarray(mf_c[r])[:valid],
                                      mu_pad[r, sl][:valid])
        np.testing.assert_array_equal(np.asarray(vf_c[r])[:valid],
                                      vu_pad[r, sl][:valid])


def test_fused_rs_adam_bf16_out_is_cast():
    n, total = 4, 256
    net = SimNetOps(n)
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal((n, total)).astype(np.float32))
    p = jnp.asarray(np.broadcast_to(
        rng.standard_normal(total).astype(np.float32), (n, total)).copy())
    wd = jnp.asarray(np.ones(total, np.int8))
    f32_fn, chunk = _fused_fn(net, n, total, wd)
    bf_fn, _ = _fused_fn(net, n, total, wd, out_dtype=jnp.bfloat16)
    m0 = jnp.zeros((n, chunk), jnp.float32)
    v0 = jnp.zeros((n, chunk), jnp.float32)
    pf, _, _ = jax.jit(f32_fn)(g, p, m0, v0)
    pb, _, _ = jax.jit(bf_fn)(g, p, m0, v0)
    assert pb.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(pb, np.float32),
                                  np.asarray(pf.astype(jnp.bfloat16),
                                             np.float32))


# ---------------------------------------------------------------------------
# k-ary combine stage: int payloads, pallas vs jnp bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_combine_chunks_matches_jnp(op, dtype):
    rng = np.random.default_rng(7)
    bufs = [jnp.asarray(rng.integers(-50, 50, size=(3, 40)).astype(dtype))
            for _ in range(3)]
    got = fu.combine_chunks(bufs, op, use_pallas=True, interpret=True)
    want = fu.combine_chunks(bufs, op, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if op == "sum":
        np.testing.assert_array_equal(
            np.asarray(want), np.asarray(bufs[0] + bufs[1] + bufs[2]))


# ---------------------------------------------------------------------------
# pricing + tuner wiring
# ---------------------------------------------------------------------------

class _StubTuner:
    def __init__(self, verdict):
        self.verdict = verdict
        self.calls = []

    def algorithm(self, collective, n, nbytes, topo=None, candidates=None,
                  team=None):
        self.calls.append((collective, n, nbytes, candidates))
        return self.verdict


def test_choose_attention_overlap_wins_when_compute_hides_comm():
    # heavy per-block compute: ring hides every rotation -> ring wins
    name, times = fusion.choose_attention(8, 1 << 20, 1.0)
    assert name == "ring" and times["ring"] < times["mono"]
    # n=1: nothing to rotate
    assert fusion.choose_attention(1, 1 << 20, 1.0)[0] == "mono"


def test_choose_grad_rs_prices_param_dtype():
    # bf16 params: the fused path allgathers half the bytes -> fused
    name, times = fusion.choose_grad_rs(8, 1 << 22, param_itemsize=2)
    assert name == "fused" and times["fused"] < times["bucketed"]
    # f32 params tie on wire bytes; ties go to fused (one kernel pass)
    name_f32, times_f32 = fusion.choose_grad_rs(8, 1 << 22, param_itemsize=4)
    assert name_f32 == "fused"
    assert times_f32["fused"] == pytest.approx(times_f32["bucketed"])


def test_choose_fused_tuner_verdict_wins():
    t = _StubTuner("mono")
    assert fusion.choose_attention(8, 1 << 20, 1.0, tuner=t)[0] == "mono"
    assert t.calls[0][0] == "attention"
    t2 = _StubTuner("bucketed")
    assert fusion.choose_grad_rs(8, 1 << 22, 2, tuner=t2)[0] == "bucketed"
    assert t2.calls[0][0] == "grad_sync"


# ---------------------------------------------------------------------------
# ops-layer executor cache: the hot path must not re-trace
# ---------------------------------------------------------------------------

def test_ops_exec_cache_retrace_free(monkeypatch):
    kops._clear_exec_cache()
    calls = {"n": 0}
    orig = kops._rc.reduce_combine_2d

    def spy(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(kops._rc, "reduce_combine_2d", spy)
    x = jnp.arange(33 * 130, dtype=jnp.float32).reshape(33, 130)
    for _ in range(5):
        out = kops.reduce_combine([x, 2.0 * x], "sum")
    assert calls["n"] == 1, "pallas wrapper re-traced on a warm call"
    assert kops._PLAN_STATS == {"hits": 4, "misses": 1}
    np.testing.assert_allclose(np.asarray(out), np.asarray(3.0 * x),
                               rtol=1e-6)
    # a different shape is a different plan, not a cache hit
    y = jnp.ones((8, 8), jnp.float32)
    kops.reduce_combine([y, y], "sum")
    assert kops._PLAN_STATS["misses"] == 2


def test_ops_put_copy_cached():
    kops._clear_exec_cache()
    x = jnp.arange(7 * 5, dtype=jnp.int32).reshape(7, 5)
    for _ in range(3):
        out = kops.put_copy(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert kops._PLAN_STATS["hits"] == 2


# ---------------------------------------------------------------------------
# SPMD subprocesses: the model-layer ring path and the fused train sync
# ---------------------------------------------------------------------------

def _run_spmd(script, ok, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert ok in r.stdout


RING_SPMD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.models import layers as L
    from repro.models.config import ModelConfig
    from repro.parallel.comm import AxisSpec, Comm

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                      dtype=jnp.float32, attention="ring")
    B, Lg, d = 2, 32, 32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, Lg, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(Lg, dtype=jnp.int32), (B, Lg))
    params = L.init_attention(jax.random.key(0), cfg, 1)

    mono = dataclasses.replace(cfg, attention="mono")
    mesh1 = make_mesh(1, 1)
    with jax.set_mesh(mesh1):
        ref = jax.jit(build.shard_mapped(
            lambda p, x, pos: L.attention(Comm(AxisSpec(), "shmem"),
                                          mono, p, x, pos),
            mesh1, (P(), P(), P()), P()))(params, x, pos)
    mesh4 = make_mesh(4, 1)
    with jax.set_mesh(mesh4):
        out = jax.jit(build.shard_mapped(
            lambda p, x, pos: L.attention(Comm(AxisSpec(), "shmem"),
                                          cfg, p, x, pos),
            mesh4, (P(), P(None, "data"), P(None, "data")),
            P(None, "data")))(params, x, pos)
    err = np.abs(np.asarray(ref, np.float32)
                 - np.asarray(out, np.float32)).max()
    assert err < 2e-5, err
    print("RING-SPMD-OK", err)
""")


def test_ring_attention_spmd_model_layer():
    """layers.attention(attention='ring') on a 4-way sequence shard equals
    the monolithic layer on the full sequence."""
    _run_spmd(RING_SPMD, "RING-SPMD-OK")


FUSED_SPMD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch import build
    from repro.launch.mesh import make_mesh
    from repro.parallel.comm import AxisSpec, Comm
    from repro.train import optimizer as opt
    from repro.train import step as tstep

    adamw = opt.AdamWConfig()
    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.standard_normal((24, 11))
                               .astype(np.float32)),
              "b": jnp.asarray(rng.standard_normal((13,))
                               .astype(np.float32))}
    mask = {"w": True, "b": True}
    n = 4
    grads = {k: jnp.asarray(rng.standard_normal((n,) + v.shape)
                            .astype(np.float32))
             for k, v in params.items()}
    mesh = make_mesh(n, 1)

    def fused(p, g):
        g = jax.tree.map(lambda a: a[0], g)     # this PE's grad shard
        comm = Comm(AxisSpec(), "shmem", grad_rs="fused")
        st = tstep.init_fused_opt_state(p, n)
        new_p, new_st = tstep.fused_adam_sync(comm, p, g, st, adamw, mask)
        return new_p

    def unfused(p, g):
        g = jax.tree.map(lambda a: a[0], g)
        comm = Comm(AxisSpec(), "shmem", grad_rs=True)
        g = tstep.fused_grad_sync(comm, g, mask)
        st = opt.init_state(p, adamw)
        new_p, _ = opt.apply_updates(p, g, st, adamw)
        return new_p

    pspec = {"w": P(), "b": P()}
    gspec = {"w": P("data"), "b": P("data")}
    with jax.set_mesh(mesh):
        a = jax.jit(build.shard_mapped(fused, mesh, (pspec, gspec),
                                       pspec))(params, grads)
        b = jax.jit(build.shard_mapped(unfused, mesh, (pspec, gspec),
                                       pspec))(params, grads)
    for k in params:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    print("FUSED-SPMD-OK")
""")


def test_fused_adam_sync_spmd_bitwise():
    """fused_adam_sync == grad_sync_bucketed-then-apply_updates BITWISE
    on the SPMD backend (4 host devices, both sides jitted)."""
    _run_spmd(FUSED_SPMD, "FUSED-SPMD-OK")
