"""Congestion-aware routing model, mesh-embedded collectives, rank remap.

Covers DESIGN.md §12: XY route enumeration and its invariants, per-link
load accounting (the acceptance inequality: snake ring strictly less
congested than the logical ring on the paper's 4x4), the congestion-priced
cost model, the wave-serial NoC simulator's bit-identity, the embedded
ring/collect executors (bitwise for data movement and int reductions,
allclose for floats), selector property tests on odd/non-square meshes,
and the greedy rank-remap pass.  SPMD coverage runs in a subprocess like
test_team/test_overlap.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax.numpy as jnp

from repro.core import abmodel, collectives as coll, sim_ctx
from repro.core import team as team_mod
from repro.core.netops import NocSimNetOps, SimNetOps
from repro.core.pattern import Stage, compile_pattern, ring_pattern
from repro.core.topology import MeshTopology, epiphany3, v5e_pod

TOPO = epiphany3()
N = TOPO.n_pes

MESHES = [
    epiphany3(),
    MeshTopology((3, 5), torus=(False, False)),
    MeshTopology((2, 7), torus=(False, False)),
    MeshTopology((1, 8), torus=(False, False)),
]
MESH_IDS = ["4x4", "3x5", "2x7", "1x8"]


# ---------------------------------------------------------------------------
# topology: validation (the zip-truncation bugfix), routes, snake orders
# ---------------------------------------------------------------------------

def test_topology_validation_rejects_mismatched_tuples():
    with pytest.raises(ValueError, match="torus"):
        MeshTopology((4, 4), torus=(False,))
    with pytest.raises(ValueError, match="link_cost"):
        MeshTopology((4, 4), link_cost=(1.0,))
    with pytest.raises(ValueError, match="extent"):
        MeshTopology((0, 4))
    with pytest.raises(ValueError, match="extent"):
        MeshTopology(())
    MeshTopology((4, 4), torus=(False, True), link_cost=(1.0, 2.0))  # ok


@pytest.mark.parametrize("topo", MESHES, ids=MESH_IDS)
def test_route_is_neighbor_steps_summing_to_hops(topo):
    for a in range(0, topo.n_pes, 3):
        for b in range(0, topo.n_pes, 2):
            r = topo.route(a, b)
            # contiguous: starts at a, ends at b, neighbor steps
            if a == b:
                assert r == ()
                continue
            assert r[0][0] == a and r[-1][1] == b
            for (u, v), (u2, _) in zip(r, r[1:]):
                assert v == u2
            for u, v in r:
                assert topo.hops(u, v) == topo.link_weight(u, v)
            assert sum(topo.link_weight(u, v) for u, v in r) \
                == pytest.approx(topo.hops(a, b))


def test_route_torus_takes_short_way_around():
    t = v5e_pod()
    wrap = t.route(t.rank((0, 15)), t.rank((0, 0)))
    assert len(wrap) == 1                 # one wrap hop, not 15 interior


def test_route_is_cached():
    assert TOPO.route(0, 15) is TOPO.route(0, 15)


@pytest.mark.parametrize("topo", MESHES + [v5e_pod()],
                         ids=MESH_IDS + ["16x16torus"])
def test_snake_order_is_hamiltonian(topo):
    order = topo.snake_order()
    assert sorted(order) == list(range(topo.n_pes))
    hops = [topo.hops(order[i], order[i + 1])
            for i in range(topo.n_pes - 1)]
    assert all(h == 1.0 for h in hops)    # interior edges: one physical hop


def test_snake_order_closes_cycle_when_possible():
    # 4x4 (even extent) and the full torus admit Hamiltonian cycles
    for topo in (epiphany3(), MeshTopology((2, 7), torus=(False, False)),
                 v5e_pod()):
        order = topo.snake_order()
        assert topo.hops(order[-1], order[0]) == 1.0, topo


# ---------------------------------------------------------------------------
# link loads: the congestion metric (and the acceptance inequality)
# ---------------------------------------------------------------------------

def test_link_loads_counts_funneled_flows():
    # i -> i+8 moves every PE two rows down its own column: successive
    # flows overlap on the middle vertical links in both directions
    p = ring_pattern(N, 8)
    loads = p.link_loads(TOPO)
    assert max(loads.values()) == 4.0     # two directions x two flows
    assert p.max_link_load(TOPO) == 4.0
    assert p.link_loads(TOPO) is loads    # interned per (pattern, topo)


def test_disjoint_neighbor_flows_are_load_one():
    p = compile_pattern([(i, i + 1) for i in range(0, N, 2)], N)
    assert p.max_link_load(TOPO) == 1.0


def test_flat_network_load_is_one():
    assert ring_pattern(N).max_link_load(None) == 1.0


def test_link_loads_are_unweighted_multiplicity():
    # a single uncontended flow over an expensive cross-pod link is still
    # load 1 — per-dimension costs belong to the hop term only
    t = MeshTopology((2, 4), torus=(False, False), link_cost=(10.0, 1.0))
    assert compile_pattern([(0, 4)], 8).max_link_load(t) == 1.0


def test_fcollect_explicit_ring_emb_defaults_to_snake(monkeypatch):
    """Explicit algorithm="ring_emb" without the knob embeds (snake), as
    allreduce does — asserted structurally (embedded vs logical fcollect
    are bitwise identical, so output equality alone would be vacuous)."""
    calls = []
    real = coll._collect_ring_embedded

    def spy(net, x, axis, order, n_chunks=1):
        calls.append(tuple(order))
        return real(net, x, axis, order, n_chunks=n_chunks)

    monkeypatch.setattr(coll, "_collect_ring_embedded", spy)
    ctx2 = sim_ctx(N, TOPO)
    x = jnp.asarray(np.random.RandomState(4).randn(N, 8).astype(np.float32))
    out = np.asarray(ctx2.fcollect(x, algorithm="ring_emb"))
    np.testing.assert_array_equal(
        out, np.asarray(ctx2.fcollect(x, algorithm="ring")))
    assert calls == [TOPO.snake_order()]


def test_snake_ring_strictly_less_congested_than_logical():
    """The acceptance inequality on the paper's chip: the snake-embedded
    ring touches every physical link at most once; the logical rank+1
    ring contends on the row-wrap columns."""
    logical = ring_pattern(N)
    embedded = logical.relabel(TOPO.snake_order(), N)
    assert embedded.max_link_load(TOPO) < logical.max_link_load(TOPO)
    assert embedded.max_link_load(TOPO) == 1.0
    # and the congestion-priced model predicts the embedded ring faster
    emb_sched = coll.allreduce_schedule(N, float(1 << 20), "ring_emb",
                                        embedding=TOPO.snake_order())
    log_sched = coll.allreduce_schedule(N, float(1 << 20), "ring")
    link = abmodel.EPIPHANY_NOC
    assert emb_sched.time(TOPO, link) < log_sched.time(TOPO, link)


def test_team_topology_routes_price_like_lifted():
    rows = team_mod.split_2d(team_mod.team_world(16), TOPO, -1)
    row1 = rows.teams[1]
    tt = row1.topo_view(TOPO)
    sched = coll.allreduce_schedule(4, 4096.0, "ring")
    assert sched.time(tt, abmodel.EPIPHANY_NOC) == pytest.approx(
        row1.lift_schedule(sched).time(TOPO, abmodel.EPIPHANY_NOC))


# ---------------------------------------------------------------------------
# cost model: the congestion term
# ---------------------------------------------------------------------------

def test_stage_cost_carries_link_load():
    st = Stage(ring_pattern(N), 1024.0)
    b, h, load = st.cost(TOPO)
    assert (b, load) == (1024.0, 2.0)
    assert st.cost(None)[2] == 1.0


def test_linkmodel_prices_serialization():
    link = abmodel.LinkModel(alpha_s=0.0, hop_s=0.0, bw_Bps=1e9)
    assert link.time(1e6, 1.0, 2.0) == pytest.approx(2 * link.time(1e6, 1.0))
    half = abmodel.LinkModel(alpha_s=0.0, hop_s=0.0, bw_Bps=1e9,
                             contention=0.5)
    assert half.time(1e6, 1.0, 3.0) == pytest.approx(2 * half.time(1e6, 1.0))


def test_model_accepts_legacy_two_tuples():
    stages2 = [(100.0, 1.0), (200.0, 2.0)]
    stages3 = [(100.0, 1.0, 1.0), (200.0, 2.0, 1.0)]
    assert abmodel.modeled_collective_time(stages2) == pytest.approx(
        abmodel.modeled_collective_time(stages3))
    assert abmodel.modeled_pipelined_time(stages2, 4) == pytest.approx(
        abmodel.modeled_pipelined_time(stages3, 4))


def test_fit_contention_recovers_gamma():
    for gamma in (0.0, 0.4, 1.0):
        loads = [1.0, 2.0, 4.0]
        times = [1e-3 * (1 + gamma * (l - 1)) for l in loads]
        assert abmodel.fit_contention(loads, times) == pytest.approx(
            gamma, abs=1e-9)


# ---------------------------------------------------------------------------
# NocSimNetOps: wave-serial execution is bit-identical
# ---------------------------------------------------------------------------

def test_link_waves_cover_pattern_disjointly():
    p = ring_pattern(N)
    waves = p.link_waves(TOPO)
    assert len(waves) == 2                # == max_link_load on the 4x4
    seen = sorted(pair for w in waves for pair in w.pairs)
    assert seen == sorted(p.pairs)
    emb = p.relabel(TOPO.snake_order(), N)
    assert len(emb.link_waves(TOPO)) == 1


def test_nocsim_bit_identical_to_sim():
    rng = np.random.RandomState(0)
    sim, noc = SimNetOps(N), NocSimNetOps(N, topo=TOPO)
    x = jnp.asarray(rng.randn(N, 13).astype(np.float32))
    xb = jnp.asarray(rng.rand(N, 7) > 0.5)
    for p in (ring_pattern(N), ring_pattern(N, 8),
              ring_pattern(N).relabel(TOPO.snake_order(), N)):
        np.testing.assert_array_equal(np.asarray(sim.ppermute(x, p)),
                                      np.asarray(noc.ppermute(x, p)))
        np.testing.assert_array_equal(np.asarray(sim.ppermute(xb, p)),
                                      np.asarray(noc.ppermute(xb, p)))


def test_nocsim_empty_pattern_returns_zeros():
    noc = NocSimNetOps(N, topo=TOPO)
    x = jnp.ones((N, 3), jnp.float32)
    out = np.asarray(noc.ppermute(x, []))
    np.testing.assert_array_equal(out, np.zeros_like(out))


def test_nocsim_preserves_narrow_dtypes():
    rng = np.random.RandomState(2)
    sim, noc = SimNetOps(N), NocSimNetOps(N, topo=TOPO)
    for dtype in (np.int8, np.uint8, np.int16):
        x = jnp.asarray(rng.randint(0, 100, (N, 9)).astype(dtype))
        a, b = sim.ppermute(x, ring_pattern(N)), noc.ppermute(x, ring_pattern(N))
        assert b.dtype == a.dtype == dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nocsim_full_collectives_match():
    rng = np.random.RandomState(1)
    xi = jnp.asarray(rng.randint(-99, 99, (N, 33)).astype(np.int32))
    a = sim_ctx(N, TOPO)
    b = sim_ctx(N, TOPO, noc=True)
    for algo in ("ring", "rd", "ring_emb"):
        np.testing.assert_array_equal(
            np.asarray(a.to_all(xi, "sum", algorithm=algo)),
            np.asarray(b.to_all(xi, "sum", algorithm=algo)))


# ---------------------------------------------------------------------------
# mesh-embedded collectives
# ---------------------------------------------------------------------------

@pytest.fixture
def ctx():
    return sim_ctx(N, TOPO)


def _f32(shape, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape)
                       .astype(np.float32))


def _i32(shape, seed=0):
    return jnp.asarray(np.random.RandomState(seed)
                       .randint(-99, 99, shape).astype(np.int32))


def test_embedded_allreduce_int_bit_identical(ctx):
    """Integer reductions are associative exactly: the embedded ring must
    be BITWISE equal to the logical ring and the plain sum."""
    x = _i32((N, 41))
    ref = np.asarray(ctx.to_all(x, "sum", algorithm="ring"))
    for chunks in (None, 4):
        out = np.asarray(ctx.to_all(x, "sum", algorithm="ring_emb",
                                    pipeline_chunks=chunks))
        np.testing.assert_array_equal(out, ref)


def test_embedded_allreduce_float_allclose(ctx):
    x = _f32((N, 129))
    ref = np.broadcast_to(np.asarray(x).sum(0), x.shape)
    for chunks in (None, 8):
        out = np.asarray(ctx.to_all(x, "sum", algorithm="ring_emb",
                                    pipeline_chunks=chunks))
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_embedded_fcollect_collect_bitwise(ctx):
    """Pure data movement: embedded and logical rings must agree BITWISE
    (block order restored by the static post-permutation)."""
    x = _f32((N, 3, 5))
    np.testing.assert_array_equal(
        np.asarray(ctx.fcollect(x, algorithm="ring")),
        np.asarray(ctx.fcollect(x, algorithm="ring_emb")))
    emb_ctx = sim_ctx(N, TOPO, embedding="snake")
    np.testing.assert_array_equal(
        np.asarray(ctx.collect(x)),
        np.asarray(emb_ctx.collect(x)))


def test_embedded_fcollect_collect_chunked_bitwise(ctx):
    """pipeline_chunks reaches the embedded ring too (the embedding team
    covers the world, so the chunked pipeline applies) and stays bitwise
    identical to the monolithic logical ring."""
    x = _f32((N, 12), seed=9)
    ref = np.asarray(ctx.fcollect(x, algorithm="ring"))
    np.testing.assert_array_equal(
        np.asarray(ctx.fcollect(x, algorithm="ring_emb",
                                pipeline_chunks=4)), ref)
    emb_ctx = sim_ctx(N, TOPO, embedding="snake")
    np.testing.assert_array_equal(
        np.asarray(emb_ctx.collect(x, pipeline_chunks=3)),
        np.asarray(ctx.collect(x)))


def test_fcollect_auto_with_team_is_team_priced(ctx):
    """algorithm='auto' under a team must price (and run) team-relative
    candidates — result equals the fixed-algorithm team fcollect."""
    x = _f32((N, 2, 4), seed=11)
    t = team_mod.make_team((0, 1, 4, 5), N)
    out = np.asarray(coll.fcollect(ctx.net, x, algorithm="auto", team=t,
                                   topo=TOPO, link=abmodel.EPIPHANY_NOC))
    fixed = np.asarray(coll.fcollect(ctx.net, x, algorithm="rd", team=t))
    np.testing.assert_allclose(out, fixed, rtol=1e-6, atol=1e-6)


def test_team_fcollect_collect_embedded_bitwise(ctx):
    """Team-scoped embedded fcollect/collect run the ring over the
    snake-reordered team but restore the ORIGINAL team-rank block order —
    bitwise identical to the plain team path, non-members still zero."""
    x = _f32((N, 2, 3), seed=13)
    cols = team_mod.split_2d(team_mod.team_world(N), TOPO, 0)
    t = cols.teams[0]
    # column 0 is genuinely reordered by the snake (0,12,8,4) — the
    # static block-order restore is exercised, not the identity fallback
    assert coll.embed_team(t, TOPO) is not t
    ref = np.asarray(coll.fcollect(ctx.net, x, team=t))
    np.testing.assert_array_equal(
        np.asarray(coll.fcollect(ctx.net, x, algorithm="ring_emb",
                                 team=t, topo=TOPO)), ref)
    np.testing.assert_array_equal(
        np.asarray(coll.collect(ctx.net, x, team=t, topo=TOPO,
                                embedding="snake")),
        np.asarray(coll.collect(ctx.net, x, team=t)))


def test_embedding_knob_on_context(ctx):
    x = _i32((N, 17), seed=3)
    ref = np.asarray(ctx.to_all(x, "sum"))
    for emb in ("snake", "auto", tuple(TOPO.snake_order())):
        ectx = sim_ctx(N, TOPO, embedding=emb)
        np.testing.assert_array_equal(
            np.asarray(ectx.to_all(x, "sum", algorithm="ring")), ref)
        # default policy embeds the ring; explicit "ring" stays logical
        np.testing.assert_array_equal(np.asarray(ectx.to_all(x, "sum")), ref)


def test_bad_embedding_rejected(ctx):
    with pytest.raises(ValueError, match="permutation"):
        coll.allreduce(ctx.net, _i32((N, 4)), embedding=(0,) * N, topo=TOPO)
    with pytest.raises(ValueError, match="unknown embedding"):
        coll.allreduce(ctx.net, _i32((N, 4)), embedding="zigzag", topo=TOPO)


def test_embedded_team_allreduce(ctx):
    """Teams compose: the embedding reorders members in TEAM coordinates
    (embed_team), non-members stay untouched."""
    x = _f32((N, 21), seed=5)
    cols = team_mod.split_2d(team_mod.team_world(N), TOPO, 0)
    col0 = cols.teams[0]
    out = np.asarray(coll.allreduce(ctx.net, x, team=col0,
                                    algorithm="auto", topo=TOPO,
                                    embedding="auto"))
    ref = np.asarray(x).copy()
    ref[list(col0.members)] = np.asarray(x)[list(col0.members)].sum(0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_explicit_ring_emb_defaults_to_snake(ctx):
    """algorithm="ring_emb" without the embedding knob must still embed
    (snake default) — on both the flat path (any pipeline depth, chunk
    count priced on the embedded stages) and the team path."""
    x = _i32((N, 19), seed=7)
    ref = np.asarray(ctx.to_all(x, "sum"))
    for chunks in (None, "auto", 4):
        np.testing.assert_array_equal(
            np.asarray(ctx.to_all(x, "sum", algorithm="ring_emb",
                                  pipeline_chunks=chunks)), ref)
    cols = team_mod.split_2d(team_mod.team_world(N), TOPO, 0)
    col0 = cols.teams[0]
    out = np.asarray(coll.allreduce(ctx.net, x, team=col0,
                                    algorithm="ring_emb", topo=TOPO))
    # must equal the explicitly reordered team's ring bitwise
    view = coll.embed_team(col0, TOPO)
    fixed = np.asarray(coll.allreduce(ctx.net, x, team=view,
                                      algorithm="ring"))
    np.testing.assert_array_equal(out, fixed)


def test_embedded_hier_allreduce(ctx):
    x = _f32((N, 37), seed=6)
    rows = team_mod.split_2d(team_mod.team_world(N), TOPO, -1)
    ref = np.broadcast_to(np.asarray(x).sum(0), x.shape)
    out = np.asarray(coll.allreduce(ctx.net, x, algorithm="hier",
                                    partition=rows, topo=TOPO,
                                    embedding="snake"))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_hier_honors_explicit_embedding_order(ctx):
    """An explicit world order reaches the hierarchical path's member
    teams (not silently replaced by the snake), and the result stays
    correct."""
    rows = team_mod.split_2d(team_mod.team_world(N), TOPO, -1)
    rev = tuple(reversed(TOPO.snake_order()))
    emb_part = coll._embed_partition(rows, TOPO, embedding=rev)
    pos = {pe: i for i, pe in enumerate(rev)}
    for orig, emb in zip(rows.teams, emb_part.teams):
        assert sorted(emb.members) == sorted(orig.members)
        assert list(emb.members) == sorted(orig.members,
                                           key=lambda p: pos[p])
    x = _f32((N, 23), seed=15)
    out = np.asarray(coll.allreduce(ctx.net, x, algorithm="hier",
                                    partition=rows, topo=TOPO,
                                    embedding=rev))
    ref = np.broadcast_to(np.asarray(x).sum(0), x.shape)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_choose_barrier_prices_lifted_team_schedules():
    """Team barrier "auto" must price the world flows that execute, not
    team ranks read as world PEs."""
    t = team_mod.split_strided(team_mod.team_world(N), 0, 5, 4)
    link = abmodel.EPIPHANY_NOC
    pick = coll.choose_barrier(t.size, TOPO, link, team=t)
    priced = {a: t.lift_schedule(coll.barrier_schedule(t.size, a))
              .time(TOPO, link) for a in ("dissem", "tree")}
    assert priced[pick] == min(priced.values())


def test_tree_barrier_token_matches_dissemination(ctx):
    one = jnp.ones((N,), jnp.int32)
    tok_tree = np.asarray(ctx.barrier(token=one, algorithm="tree"))
    assert (tok_tree == N).all()          # gather+bcast: everyone sees all
    tok_auto = np.asarray(ctx.barrier(token=one, algorithm="auto"))
    assert tok_auto.shape == tok_tree.shape
    with_team = team_mod.make_team((0, 3, 5, 9), N)
    tok_team = np.asarray(ctx.barrier(token=one, team=with_team,
                                      algorithm="tree"))
    assert len({int(tok_team[m]) for m in with_team.members}) == 1


# ---------------------------------------------------------------------------
# selector property tests on odd / non-square / degenerate meshes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", MESHES[1:], ids=MESH_IDS[1:])
@pytest.mark.parametrize("nbytes", [64.0, float(1 << 16), float(1 << 21)])
def test_choose_schedule_execution_equivalent_on_odd_meshes(topo, nbytes):
    """Whatever (algorithm, chunks) the congestion-priced selector picks
    on an odd/non-square mesh, executing it must equal the eager flat
    allreduce — exactly for ints, allclose for floats."""
    n = topo.n_pes
    link = abmodel.EPIPHANY_NOC
    algo, chunks = coll.choose_schedule(n, nbytes, topo, link,
                                        embedding="auto")
    ctx2 = sim_ctx(n, topo)
    xi = _i32((n, 29), seed=int(nbytes) % 97)
    refi = np.broadcast_to(np.asarray(xi).sum(0), xi.shape)
    outi = np.asarray(ctx2.to_all(xi, "sum", algorithm=algo,
                                  pipeline_chunks=chunks))
    np.testing.assert_array_equal(outi, refi)
    xf = _f32((n, 29), seed=int(nbytes) % 89)
    reff = np.broadcast_to(np.asarray(xf).sum(0), xf.shape)
    outf = np.asarray(ctx2.to_all(xf, "sum", algorithm=algo,
                                  pipeline_chunks=chunks))
    np.testing.assert_allclose(outf, reff, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("topo", MESHES, ids=MESH_IDS)
def test_choose_algorithm_pick_is_cheapest_candidate(topo):
    n = topo.n_pes
    link = abmodel.EPIPHANY_NOC
    for nbytes in (8.0, float(1 << 20)):
        emb = coll.choose_embedding(n, topo, link)
        algo = coll.choose_algorithm(n, nbytes, topo, link,
                                     embedding="auto")
        priced = {"ring": coll.allreduce_schedule(n, nbytes, "ring")
                  .time(topo, link)}
        if n & (n - 1) == 0:
            priced["rd"] = coll.allreduce_schedule(n, nbytes, "rd") \
                .time(topo, link)
        if emb is not None:
            priced["ring_emb"] = coll.allreduce_schedule(
                n, nbytes, "ring_emb", embedding=emb).time(topo, link)
        assert priced[algo] == min(priced.values())


def test_choose_schedule_picks_embedded_ring_large_on_epiphany():
    """The acceptance configuration: on the 4x4 at large payloads the
    congestion-priced selector must take the embedded ring."""
    algo, chunks = coll.choose_schedule(N, float(1 << 20), TOPO,
                                        abmodel.EPIPHANY_NOC,
                                        embedding="auto")
    assert algo == "ring_emb"
    small_algo, _ = coll.choose_schedule(N, 64.0, TOPO,
                                         abmodel.EPIPHANY_NOC,
                                         embedding="auto")
    assert small_algo in ("rd", "ring")


# ---------------------------------------------------------------------------
# rank remapping
# ---------------------------------------------------------------------------

def test_optimize_embedding_monotone_and_valid():
    sched = coll.allreduce_schedule(N, float(1 << 20), "ring")
    link = abmodel.EPIPHANY_NOC
    remapped, perm = coll.optimize_embedding(sched, TOPO, link)
    assert sorted(perm) == list(range(N))
    assert remapped.time(TOPO, link) <= sched.time(TOPO, link) + 1e-15
    assert max(st.pattern.max_link_load(TOPO) for st in remapped.stages) \
        <= max(st.pattern.max_link_load(TOPO) for st in sched.stages)


def test_choose_embedding_beats_identity_on_epiphany():
    order = coll.choose_embedding(N, TOPO, abmodel.EPIPHANY_NOC)
    assert order is not None
    ring = ring_pattern(N).relabel(order, N)
    assert ring.max_link_load(TOPO) == 1.0
    # 1D line: identity IS the snake; no embedding to pick
    line = MeshTopology((8,), torus=(False,))
    assert coll.choose_embedding(8, line, abmodel.EPIPHANY_NOC) is None


def test_embedding_cache_interns_teams():
    t1 = coll.embedding_team("snake", TOPO, N)
    t2 = coll.embedding_team("snake", TOPO, N)
    assert t1 is t2 and t1.members == TOPO.snake_order()


# ---------------------------------------------------------------------------
# SPMD backend (subprocess, 8 host devices, 2x4 mesh)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import _compat
from repro.core import collectives as coll, spmd_ctx
from repro.core.topology import MeshTopology
from repro.parallel.comm import AxisSpec, Comm

topo = MeshTopology((2, 4), torus=(False, False))
mesh = jax.make_mesh((8,), ("pe",))
x = np.arange(8 * 6, dtype=np.int32).reshape(8, 6)
xf = np.random.RandomState(0).randn(8, 6).astype(np.float32)

def run(fn, v):
    g = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("pe"),),
                              out_specs=P("pe"), check_vma=False))
    return np.asarray(g(v))

def emb_int(v):
    ctx = spmd_ctx("pe", topo, embedding="snake")
    return ctx.to_all(v, "sum", algorithm="ring_emb")

def log_int(v):
    ctx = spmd_ctx("pe", topo)
    return ctx.to_all(v, "sum", algorithm="ring")

a, b = run(emb_int, x), run(log_int, x)
assert np.array_equal(a, b), (a, b)

def emb_fc(v):
    ctx = spmd_ctx("pe", topo, embedding="auto")
    return ctx.fcollect(v)

def log_fc(v):
    ctx = spmd_ctx("pe", topo)
    return ctx.fcollect(v)

a, b = run(emb_fc, xf), run(log_fc, xf)
assert np.array_equal(a, b), "embedded fcollect must be bitwise identical"

def comm_emb(v):
    c = Comm(AxisSpec(data="pe", model=None), "shmem",
             allreduce_algo="auto", topo=topo, embedding="auto")
    return c.allreduce(v, "pe")

out = run(comm_emb, xf)
ref = np.broadcast_to(xf.sum(0), xf.shape)
assert np.allclose(out, ref, rtol=1e-5, atol=1e-5)

# grad sync in embedded coordinates: the reduce-scatter + allgather pair
# and the bucketed interleave (incl. _hier_wins' embedded-flat pricing)
def gs_emb(v):
    c = Comm(AxisSpec(data="pe", model=None), "shmem", grad_rs=True,
             topo=topo, embedding="snake")
    return c.grad_sync(v, mean=True)

def gs_bucketed(v):
    c = Comm(AxisSpec(data="pe", model=None), "shmem",
             allreduce_algo="auto", topo=topo, embedding="snake")
    return tuple(c.grad_sync_bucketed([v, v * 2.0], mean=True))

mref = np.broadcast_to(xf.mean(0), xf.shape)
assert np.allclose(run(gs_emb, xf), mref, rtol=1e-5, atol=1e-5)
b1, b2 = jax.jit(jax.shard_map(gs_bucketed, mesh=mesh, in_specs=(P("pe"),),
                               out_specs=(P("pe"), P("pe")),
                               check_vma=False))(xf)
assert np.allclose(np.asarray(b1), mref, rtol=1e-5, atol=1e-5)
assert np.allclose(np.asarray(b2), 2.0 * mref, rtol=1e-5, atol=1e-5)

def tree_barrier(v):
    ctx = spmd_ctx("pe", topo)
    tok = ctx.barrier(token=jnp.ones((), jnp.int32), algorithm="tree")
    return v + tok[None].astype(v.dtype) * 0

assert run(tree_barrier, x).shape == x.shape

# an explicit data-axis rank order must NOT leak to the pod axis (whose
# PE count it is not a permutation of) — grad sync crosses both axes
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
topo4 = MeshTopology((2, 2), torus=(False, False))

def gs_pod(v):
    c = Comm(AxisSpec(data="data", model=None, pod="pod"), "shmem",
             grad_rs=True, topo=topo4, embedding=(0, 1, 3, 2))
    return c.grad_sync(v, mean=True)

g = jax.jit(jax.shard_map(gs_pod, mesh=mesh2,
                          in_specs=(P(("pod", "data")),),
                          out_specs=P(("pod", "data")), check_vma=False))
out2 = np.asarray(g(xf))
assert np.allclose(out2, np.broadcast_to(xf.mean(0), xf.shape),
                   rtol=1e-5, atol=1e-5)
print("SPMD_CONGESTION_OK")
"""


def test_spmd_embedded_collectives_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SPMD_CONGESTION_OK" in r.stdout
